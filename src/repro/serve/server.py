"""The `Server` facade: threaded admission + flush worker (DESIGN.md §8).

One object owns the whole serving path.  Many producer threads call
``submit()``; a single dedicated flush worker owns the
:class:`~repro.serve.batching.BucketBatcher` (its lock is the only thing
producers and the worker contend on) and drains it on size or deadline.
A bounded admission queue (``ServeConfig.queue_capacity``) gives
backpressure with an explicit overload policy — ``block`` producers,
``shed`` the request, or ``degrade`` to eager smaller-bucket flushes —
and per-request deadlines expire queued work instead of serving stale
results.

The worker double-buffers host<->device staging: while bucket ``k``
computes on device, bucket ``k+1`` is padded and ``jax.device_put`` (and,
on backends that implement donation, its staged buffer is donated to the
executable — ``engine.execute.executable_for``).  ``np.asarray`` /
``jax.block_until_ready`` happens only at result hand-off, so transfer
and compute overlap across flushes (``ServeMetrics.overlapped`` counts
the flushes that actually pipelined).

``run_stream(stream, producers=0)`` keeps the PR-6 single-threaded open
loop — deterministic on an injected clock, and byte-for-byte the metrics
the deprecated ``serve_stream`` produced; ``producers >= 1`` partitions
the arrival-timed stream across that many real producer threads and
serves it through the worker.  Construct via ``Server.from_plan(plan,
params, ServeConfig(...))`` — the serving-side mirror of
``ExecutionPolicy -> plan_model`` (§3).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterable, List, Optional

import numpy as np

from repro.serve.batching import BucketBatcher, Request, pad_batch
from repro.serve.config import ServeConfig
from repro.serve.metrics import ServeMetrics


class Server:
    """Unified serving facade: ``submit`` / ``run_stream`` / ``drain`` /
    ``close`` over one compile-once engine + one frozen ServeConfig."""

    def __init__(
        self,
        engine,
        config: ServeConfig = ServeConfig(),
        *,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        batcher: Optional[BucketBatcher] = None,
        metrics: Optional[ServeMetrics] = None,
    ):
        if tuple(engine.buckets) != tuple(config.buckets):
            raise ValueError(
                f"engine buckets {engine.buckets} != config buckets "
                f"{config.buckets}: one ServeConfig must describe both")
        self.engine = engine
        self.config = config
        self._clock = clock
        self._sleep = sleep
        self._real_clock = clock is time.monotonic
        self.batcher = batcher or BucketBatcher(
            config.buckets, max_delay_s=config.max_delay_s, clock=clock)
        self.metrics = metrics or ServeMetrics(config.buckets)
        #: every admitted request handle, in admission order (what
        #: ``metrics.requests`` is set to at stream end)
        self.requests: List[Request] = []
        self._cv = threading.Condition()
        self._worker: Optional[threading.Thread] = None
        self._running = False
        self._draining = False
        self._closed = False

    @classmethod
    def from_plan(
        cls,
        plan,
        params,
        config: ServeConfig = ServeConfig(),
        *,
        requant=None,
        warm: bool = True,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ) -> "Server":
        """A server for one :class:`~repro.engine.ModelPlan`: builds the
        compile-once engine (one AOT executable per bucket, warmed before
        the first request) and wraps it in the facade.  The int8 datapath
        requires calibrated ``requant`` pairs, exactly as the engine
        does."""
        from repro.serve.engine import ServeEngine

        engine = ServeEngine.build_for_plan(
            plan, params, buckets=config.buckets,
            datapath=config.datapath, requant=requant, warm=warm)
        return cls(engine, config, clock=clock, sleep=sleep)

    # -- lifecycle ------------------------------------------------------

    def start(self) -> "Server":
        """Spawn the flush worker (idempotent; ``submit`` auto-starts)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("start() on a closed Server")
            if self._running:
                return self
            self._running = True
            self._worker = threading.Thread(
                target=self._worker_loop,
                name=f"serve-flush-{self.engine.name}", daemon=True)
            self._worker.start()
        return self

    def drain(self, timeout_s: float = 60.0) -> None:
        """Block until every admitted request reached a terminal state
        (served or expired) — queued work is force-flushed sub-bucket."""
        with self._cv:
            worker = self._worker
            if worker is not None:
                self._draining = True
                pending = [r for r in self.requests if not r.done.is_set()]
                self._cv.notify_all()
        if worker is None:
            self._flush_ready(force=True)
            return
        end = time.monotonic() + timeout_s
        try:
            for r in pending:
                if not r.done.wait(max(end - time.monotonic(), 0.0)):
                    raise TimeoutError(
                        f"drain: request {r.rid} not completed within "
                        f"{timeout_s}s (flush worker stuck?)")
        finally:
            with self._cv:
                self._draining = False

    def close(self, timeout_s: float = 60.0) -> None:
        """Drain, stop the flush worker, and reject further submits.
        Producers must have stopped submitting (close is the shutdown
        hand-off, not a cancellation)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
        self.drain(timeout_s=timeout_s)
        with self._cv:
            worker = self._worker
            self._running = False
            self._cv.notify_all()
        if worker is not None:
            # join OUTSIDE the cv: the worker needs it to observe _running.
            worker.join(timeout=timeout_s)
            if worker.is_alive():
                raise TimeoutError("close: flush worker did not exit")
            with self._cv:
                self._worker = None

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- admission ------------------------------------------------------

    def _admit(self, payload: Any, now: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Shed-or-enqueue + counters (the non-blocking piece shared by
        ``submit`` and the inline open loop).  Caller holds no locks the
        batcher needs; ``requests`` append is atomic under the GIL."""
        t = self._clock() if now is None else float(now)
        if deadline_s is None and self.config.request_timeout_s is not None:
            deadline_s = t + self.config.request_timeout_s
        cap = self.config.queue_capacity
        if (cap and self.config.overload == "shed"
                and self.batcher.depth >= cap):
            r = Request(self.batcher.take_rid(), payload, t,
                        deadline_s=deadline_s)
            r.status = "shed"
            r.done.set()
            self.metrics.record_submit()
            self.metrics.record_shed()
        else:
            r = self.batcher.submit(payload, now=now, deadline_s=deadline_s)
            self.metrics.record_submit()
        # trimcheck: disable=lock-guarded-attr -- list.append is GIL-atomic;
        # threaded callers (submit) already hold the cv, inline mode is
        # single-threaded, and readers snapshot under the cv (drain).
        self.requests.append(r)
        return r

    def submit(self, payload: Any, *, deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> Request:
        """Thread-safe admission: enqueue one request for the flush
        worker; returns its handle (wait on ``r.done``; ``r.status``
        lands on served / shed / expired).  Under the ``block`` overload
        policy a full queue makes this call wait for space — that is the
        backpressure."""
        self.start()
        cfg = self.config
        with self._cv:
            if self._closed:
                raise RuntimeError("submit() on a closed Server")
            if cfg.queue_capacity and cfg.overload == "block":
                while (self.batcher.depth >= cfg.queue_capacity
                       and self._running):
                    self._cv.wait(0.05)
            r = self._admit(payload, now=now, deadline_s=deadline_s)
            self._cv.notify_all()
        return r

    # -- the flush path (worker-owned in threaded mode) -----------------

    def _finish_expired(self, r: Request) -> None:
        r.status = "expired"
        self.metrics.record_expired()
        r.done.set()

    def _dispatch(self, bucket: int, reqs: List[Request]):
        """Stage one batch (pad + device_put) and launch its compute
        asynchronously.  Called back-to-back with a prior in-flight
        batch, the device_put here overlaps that batch's compute — the
        double-buffering."""
        t0 = self._clock()
        depth = self.batcher.depth
        staged = self.engine.stage(
            pad_batch([r.payload for r in reqs], bucket))
        out = self.engine.run_bucket(bucket, staged)
        return (bucket, reqs, out, t0, depth)

    def _finalize(self, dispatched) -> None:
        """Result hand-off: the ONLY place the flush path blocks on
        device work (np.asarray == block_until_ready)."""
        bucket, reqs, out, t0, depth = dispatched
        arr = np.asarray(out)
        t1 = self._clock()
        for i, r in enumerate(reqs):
            r.result = arr[i]
            r.status = "served"
            r.done.set()
        self.metrics.record_flush(
            bucket, len(reqs), batch_s=t1 - t0,
            latencies_s=[t1 - r.t_submit for r in reqs],
            queue_depth=depth)

    def _overloaded_degrade(self) -> bool:
        cap = self.config.queue_capacity
        return bool(cap and self.config.overload == "degrade"
                    and self.batcher.depth >= cap)

    def _flush_ready(self, force: bool = False) -> None:
        """Inline flush: expire + serve every currently-shippable batch
        synchronously (the single-threaded open loop's arm — no staging
        overlap; the threaded pipeline lives in ``_worker_loop``)."""
        while True:
            now = self._clock()
            for r in self.batcher.purge_expired(now):
                self._finish_expired(r)
            got = self.batcher.poll(now=now, force=force)
            if got is None:
                return
            self._finalize(self._dispatch(*got))

    def _worker_loop(self) -> None:
        """The dedicated flush worker: the one consumer of the batcher.

        Keeps at most one batch in flight on device; when a second batch
        becomes shippable it is staged and launched BEFORE the in-flight
        one is finalized, so its transfer overlaps the running compute.
        Exits when the server stops and the queue is drained.
        """
        inflight = None
        while True:
            with self._cv:
                now = self._clock()
                expired = self.batcher.purge_expired(now)
                eager = (self._draining or not self._running
                         or self._overloaded_degrade())
                got = self.batcher.poll(now=now, force=eager)
                if expired or got:
                    # queue depth dropped: wake block-policy producers
                    self._cv.notify_all()
                if got is None and not expired and inflight is None:
                    if not self._running and self.batcher.depth == 0:
                        self._cv.notify_all()
                        return
                    dl = self.batcher.next_deadline()
                    # An injected clock may not advance with real time, so
                    # cap the real-time cv wait and re-read it frequently.
                    cap = None if self._real_clock else 0.05
                    timeout = cap if dl is None else max(dl - now, 0.0)
                    if cap is not None and timeout is not None:
                        timeout = min(timeout, cap)
                    self._cv.wait(timeout)
                    continue
            for r in expired:
                self._finish_expired(r)
            if got is not None:
                nxt = self._dispatch(*got)  # stage while inflight computes
                if inflight is not None:
                    self.metrics.record_overlap()
                    self._finalize(inflight)
                inflight = nxt
            elif inflight is not None:
                self._finalize(inflight)
                inflight = None

    # -- stream drivers -------------------------------------------------

    def run_stream(self, stream: Iterable, *, producers: int = 0) -> ServeMetrics:
        """Serve an arrival-timed request stream; returns filled metrics.

        ``producers == 0``: the deterministic single-threaded open loop
        (admit at arrival times on the injected clock, flush size- and
        deadline-triggered batches inline) — the PR-6 ``serve_stream``
        semantics, still what the fake-clock tests and the concurrency
        benchmark's baseline arm drive.  ``producers >= 1``: partition
        the stream round-robin across that many real producer threads
        submitting through :meth:`submit` while the flush worker drains.
        """
        if producers and producers > 0:
            return self._run_stream_threaded(stream, int(producers))
        return self._run_stream_inline(stream)

    def _run_stream_inline(self, stream: Iterable) -> ServeMetrics:
        cfg = self.config
        t0 = self._clock()
        for item in stream:
            t_arr, payload = float(item[0]), item[1]
            while self._clock() - t0 < t_arr:
                deadline = self.batcher.next_deadline()
                now = self._clock()
                if deadline is not None and deadline <= now:
                    self._flush_ready()
                    continue
                wait = t0 + t_arr - now
                if deadline is not None:
                    wait = min(wait, deadline - now)
                self._sleep(max(wait, 0.0))
            if (cfg.queue_capacity and cfg.overload in ("block", "degrade")
                    and self.batcher.depth >= cfg.queue_capacity):
                # The inline loop IS the flush worker, so both waiting
                # for space (block) and eager draining (degrade) mean the
                # same thing here: ship what is queued, sub-bucket, now.
                self._flush_ready(force=True)
            self._admit(payload)
            self._flush_ready()
        self._flush_ready(force=True)
        self.metrics.wall_s = self._clock() - t0
        # trimcheck: disable=lock-guarded-attr -- inline loop: no flush
        # worker exists, the stream ran on this one thread.
        self.metrics.requests = self.requests
        return self.metrics

    def _run_stream_threaded(self, stream: Iterable,
                             producers: int) -> ServeMetrics:
        items = list(stream)
        self.start()
        t0 = self._clock()

        def producer(k: int) -> None:
            for item in items[k::producers]:
                t_arr = float(item[0])
                while True:
                    now = self._clock()
                    if now - t0 >= t_arr:
                        break
                    self._sleep(min(t_arr - (now - t0), 0.05))
                self.submit(item[1])

        threads = [
            threading.Thread(target=producer, args=(k,),
                             name=f"serve-producer-{k}", daemon=True)
            for k in range(producers)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        self.drain()
        self.metrics.wall_s = self._clock() - t0
        # trimcheck: disable=lock-guarded-attr -- producers joined and
        # drain() returned: the request list is quiescent here.
        self.metrics.requests = list(self.requests)
        return self.metrics
