"""Sharded checkpointing with async write and elastic restore."""

from repro.checkpoint.manager import (
    CheckpointManager,
    latest_step,
    save_pytree,
    restore_pytree,
)
