"""Checkpointing: per-leaf npz shards + JSON manifest, async writer,
atomic commit, elastic (cross-mesh) restore.

Layout:  <dir>/step_<k>/
            manifest.json      paths, shapes, dtypes
            <leafhash>.npy     one file per pytree leaf
            COMMITTED          empty marker written LAST (atomic validity)

Restore never requires the saving mesh: leaves are loaded host-side and
``jax.device_put`` re-shards them onto the *current* mesh's PartitionSpecs
(elastic rescale). A torn checkpoint (no COMMITTED) is skipped by
``latest_step`` — the fault-tolerance contract the trainer relies on.

On a real multi-host pod each host writes only the shards it owns
(process-local addressable shards); in this single-process container that
degenerates to full arrays, but the manifest format and the commit protocol
are the multi-host ones.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

#: dtypes numpy can't natively serialize -> (view dtype, restore dtype)
_EXOTIC = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _leaf_file(path_str: str) -> str:
    h = hashlib.sha1(path_str.encode()).hexdigest()[:16]
    return f"leaf_{h}.npy"


def save_pytree(tree, directory: str) -> None:
    os.makedirs(directory, exist_ok=True)
    manifest: Dict[str, Any] = {"leaves": []}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        ps = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_file(ps)
        dtype_name = str(arr.dtype)
        if dtype_name in _EXOTIC:
            arr = arr.view(_EXOTIC[dtype_name][0])
        np.save(os.path.join(directory, fname), arr)
        manifest["leaves"].append(
            {"path": ps, "file": fname, "shape": list(arr.shape), "dtype": dtype_name}
        )
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic commit marker — written last
    with open(os.path.join(directory, "COMMITTED"), "w") as f:
        f.write("ok")


def restore_pytree(template, directory: str, shardings=None):
    """Restore into the structure of `template`. `shardings` (optional
    matching pytree of jax.sharding.Sharding) re-shards on the current mesh
    — the elastic-restore path."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {l["path"]: l for l in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings)
        if shardings is not None
        else [None] * len(flat)
    )
    out: List[Any] = []
    for (path, leaf), shd in zip(flat, shard_flat):
        ps = _path_str(path)
        if ps not in by_path:
            raise KeyError(f"checkpoint missing leaf {ps!r}")
        entry = by_path[ps]
        arr = np.load(os.path.join(directory, entry["file"]))
        if entry["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[entry["dtype"]][1])
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{ps}: checkpoint shape {arr.shape} != template {want_shape}"
            )
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None and arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jax.device_put(arr))
    return treedef.unflatten(out)


def latest_step(base_dir: str) -> Optional[int]:
    """Largest committed step directory, or None."""
    if not os.path.isdir(base_dir):
        return None
    steps = []
    for name in os.listdir(base_dir):
        if name.startswith("step_"):
            d = os.path.join(base_dir, name)
            if os.path.exists(os.path.join(d, "COMMITTED")):
                try:
                    steps.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
    return max(steps) if steps else None


class CheckpointManager:
    """Async (background-thread) checkpoint writer with retention."""

    def __init__(self, base_dir: str, keep_last: int = 3, async_write: bool = True):
        self.base_dir = base_dir
        self.keep_last = keep_last
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(base_dir, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.base_dir, f"step_{step}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_", 1)[1])
            for n in os.listdir(self.base_dir)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    def save(self, tree, step: int) -> None:
        """Snapshot to host memory synchronously, write to disk async."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(host_tree, self._dir(step))
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore_latest(self, template, shardings=None) -> Tuple[Optional[int], Any]:
        step = latest_step(self.base_dir)
        if step is None:
            return None, template
        return step, restore_pytree(template, self._dir(step), shardings)
