"""Batched serving example: prefill a batch of prompts, then greedy-decode
continuations through the KV/SSM-cache path.

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --gen 24
  PYTHONPATH=src python examples/serve_lm.py --arch granite-3-2b --smoke
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.nn.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.with_overrides(dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )

    cache = model.init_cache(args.batch, max_len, dtype=jnp.float32)
    t0 = time.perf_counter()
    logits, cache = jax.jit(model.prefill)(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack([np.asarray(t) for t in generated], 1)
    print(
        f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
        f"{t_prefill*1e3:.0f} ms; decode "
        f"{args.batch * (args.gen - 1)} tokens in {t_decode*1e3:.0f} ms "
        f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s)"
    )
    print(f"[serve] continuation[0]: {gen[0].tolist()}")


if __name__ == "__main__":
    main()
