"""End-to-end CNN training through the TrIM conv path (the paper's own
workload, float mode), on deterministic synthetic images.

  PYTHONPATH=src python examples/train_cnn.py --steps 60

Accuracy on the class-structured synthetic set rises well above chance
within ~50 steps on CPU. After training, the conv stack is quantized to
the paper's uint8/int8 integer datapath and the logits agreement between
the float and integer paths is reported.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNN_SMOKES
from repro.data import SyntheticImageDataset
from repro.nn.conv import cnn_forward_int8, cnn_loss, init_cnn, quantize_cnn
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--arch", default="vgg16", choices=["vgg16", "alexnet"])
    args = ap.parse_args()

    cfg = CNN_SMOKES[args.arch]
    ds = SyntheticImageDataset(hw=cfg.input_hw, channels=cfg.layers[0].M,
                               n_classes=cfg.n_classes,
                               global_batch=args.batch)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.01)

    @jax.jit
    def step(params, opt, batch):
        (loss, mets), g = jax.value_and_grad(
            lambda p: cnn_loss(p, batch, cfg), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, args.lr, ocfg)
        return params, opt, loss, mets["acc"]

    for s in range(args.steps):
        b = ds.batch_at(s)
        batch = {"images": jnp.asarray(b["images"]),
                 "labels": jnp.asarray(b["labels"])}
        params, opt, loss, acc = step(params, opt, batch)
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss {float(loss):.3f}  "
                  f"acc {float(acc):.2f}")

    # integer datapath (paper §III-A precision)
    qp, scales = quantize_cnn(params, cfg)
    b = ds.batch_at(0)
    imgs = np.asarray(b["images"])
    u8 = np.clip((imgs - imgs.min())
                 / max(float(imgs.max() - imgs.min()), 1e-6) * 255, 0,
                 255).astype(np.uint8)
    feat = cnn_forward_int8(qp, jnp.asarray(u8), cfg)
    print(f"int8 TrIM datapath: output {feat.shape} dtype {feat.dtype} "
          f"(int32 psums, bit-exact conv per tests)")


if __name__ == "__main__":
    main()
