"""End-to-end CNN training through the TrIM conv path (the paper's own
workload, float mode), on deterministic synthetic images — written against
the execution-plan API (``repro.engine``, DESIGN.md §3).

  PYTHONPATH=src python examples/train_cnn.py --steps 60

``plan_model(cfg, policy)`` compiles the per-layer TrIM kernel schedule
once; training, quantization, requant calibration, and the fused int8
inference datapath all run off the same ``ModelPlan``.  Accuracy on the
class-structured synthetic set rises well above chance within ~50 steps on
CPU; afterwards the float/int8 agreement is reported.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNN_SMOKES
from repro.data import SyntheticImageDataset
from repro.engine import ExecutionPolicy, plan_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--arch", default="vgg16", choices=["vgg16", "alexnet"])
    ap.add_argument(
        "--substrate",
        default="auto",
        choices=["auto", "pallas", "oracle", "interpret"],
        help="kernel substrate (ExecutionPolicy)",
    )
    args = ap.parse_args()

    cfg = CNN_SMOKES[args.arch]
    # The plan is the whole execution story: substrate + per-layer schedule,
    # resolved once — no kernel kwargs thread through the training step.
    plan = plan_model(cfg, ExecutionPolicy(substrate=args.substrate))
    ds = SyntheticImageDataset(
        hw=cfg.input_hw,
        channels=cfg.layers[0].M,
        n_classes=cfg.n_classes,
        global_batch=args.batch,
    )
    params = plan.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.01)

    @jax.jit
    def step(params, opt, batch):
        (loss, mets), g = jax.value_and_grad(
            lambda p: plan.loss(p, batch), has_aux=True
        )(params)
        params, opt, _ = adamw_update(g, opt, params, args.lr, ocfg)
        return params, opt, loss, mets["acc"]

    for s in range(args.steps):
        b = ds.batch_at(s)
        batch = {"images": jnp.asarray(b["images"]), "labels": jnp.asarray(b["labels"])}
        params, opt, loss, acc = step(params, opt, batch)
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss {float(loss):.3f}  acc {float(acc):.2f}")

    # integer datapath (paper §III-A precision), same plan: quantize,
    # calibrate the per-channel fused requant, run fully fused.
    qp, scales = plan.quantize(params)
    b = ds.batch_at(0)
    imgs = np.asarray(b["images"])
    lo, hi = float(imgs.min()), float(imgs.max())
    u8 = np.clip((imgs - lo) / max(hi - lo, 1e-6) * 255, 0, 255).astype(np.uint8)
    pairs = plan.calibrate_requant(qp, jnp.asarray(u8))
    feat = plan.forward_int8(qp, jnp.asarray(u8), requant=pairs)
    print(
        f"int8 TrIM datapath: output {feat.shape} dtype {feat.dtype} "
        f"(int32 psums, fused per-channel requant, bit-exact per tests)"
    )


if __name__ == "__main__":
    main()
