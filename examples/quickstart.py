"""Quickstart tour of the framework's public API.

  PYTHONPATH=src python examples/quickstart.py

1. The paper's TrIM dataflow: cycle-level slice simulation, the
   bit-faithful engine, and the analytical model (Table I numbers).
2. The TPU-native TrIM conv kernel (Pallas, interpret mode on CPU).
3. A tiny LM: one train step + greedy decode through the serve path.
4. The sub-8-bit MSR weight lane: 5-bit packed weights, expect-value
   compensation, and the 5/8 weight-traffic ratio (DESIGN.md §9.3).
"""

import numpy as np

import jax
import jax.numpy as jnp


def demo_trim_dataflow():
    from repro.core.trim.slice_sim import simulate_slice, padding_overhead
    from repro.core.trim.engine import TrimEngine, reference_conv_layer
    from repro.core.trim.model import VGG16_LAYERS, PAPER_ENGINE, network_gops

    print("=== 1. TrIM dataflow (the paper) ===")
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (12, 12)).astype(np.int64)
    w = rng.integers(-8, 8, (3, 3))
    r = simulate_slice(x, w)
    print(
        f"slice sim: {r.external_fetches} external fetches "
        f"(= padded elements, fetched ONCE), fifo_ok={r.fifo_order_ok}"
    )
    print(
        f"224x224 input-fetch overhead: "
        f"{100 * padding_overhead(224, 224, 3):.2f}%  (paper: ~1.8%)"
    )

    xs = rng.integers(0, 256, (8, 14, 14), dtype=np.uint8)
    ws = rng.integers(-128, 128, (4, 8, 3, 3)).astype(np.int8)
    out, trace = TrimEngine().run_layer(xs, ws)
    ok = (out == reference_conv_layer(xs, ws)).all()
    print(
        f"engine: int8 conv bit-exact={bool(ok)}, "
        f"steps={trace.steps}, psum accesses={trace.psum_buffer_accesses}"
    )
    print(
        f"peak: {PAPER_ENGINE.peak_gops} GOPs/s; VGG-16 sustained "
        f"{network_gops(VGG16_LAYERS):.0f} GOPs/s (paper: 391)"
    )


def demo_kernel():
    from repro.engine import ExecutionPolicy, plan_conv_layer
    from repro.kernels.ops import trim_conv2d

    print("\n=== 2. TrIM Pallas kernel (interpret mode) ===")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 16, 16, 8))
    w = jax.random.normal(key, (3, 3, 8, 16))
    # ExecutionPolicy says HOW to run (substrate / emulate_hw / tiling);
    # "pallas" runs the TrIM kernels everywhere — interpret mode off-TPU.
    out = trim_conv2d(x, w, policy=ExecutionPolicy(substrate="pallas"))
    ref = trim_conv2d(x, w)  # auto policy: CPU oracle off-TPU
    print(
        f"conv2d {x.shape} * {w.shape} -> {out.shape}; "
        f"max err vs oracle: {float(jnp.abs(out - ref).max()):.2e}"
    )
    plan = plan_conv_layer(
        (16, 16),
        8,
        3,
        16,
        relu=True,
        has_bias=True,
        policy=ExecutionPolicy(substrate="pallas"),
    )
    print(f"layer plan (compiled once, DESIGN.md §3): {plan.describe()}")


def demo_lm():
    from repro.configs import get_smoke
    from repro.nn.models import build_model
    from repro.distributed import StepConfig, make_train_state, make_train_step

    print("\n=== 3. Tiny LM: train step + decode ===")
    cfg = get_smoke("granite-3-2b")
    model = build_model(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, StepConfig(total_steps=10, warmup_steps=1)))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 33)), jnp.int32)}
    state, metrics = step(state, batch)
    print(
        f"train step: loss={float(metrics['loss']):.3f} "
        f"grad_norm={float(metrics['grad_norm']):.3f}"
    )

    cache = model.init_cache(2, 16, dtype=jnp.float32)
    prompt = batch["tokens"][:, :8]
    logits, cache = model.prefill(state["params"], prompt, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    for i in range(4):
        logits, cache = model.decode_step(state["params"], tok, cache, jnp.int32(8 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    print("greedy decode:", [int(t[0]) for t in outs])


def demo_int5():
    from repro.core.trim.model import PAPER_ENGINE, VGG16_LAYERS, trim_memory_accesses
    from repro.core.trim.quant import msr_compress, msr_operand, pack_int5, unpack_int5

    print("=== 4. int5 MSR weight lane (DESIGN.md §9.3) ===")
    rng = np.random.default_rng(0)
    w = rng.integers(-127, 128, (3, 3, 8, 16)).astype(np.int8)
    codes, shifts = msr_compress(w)  # sign + 4-bit MSR, t per channel
    w5, e = msr_operand(codes, shifts)  # exact w_hat == w5 << e
    packed = pack_int5(codes)  # 5 bits/weight on the wire
    assert (unpack_int5(packed, w.size) == codes.reshape(-1)).all()
    err = np.abs((np.int32(w5) << e) - w.astype(np.int32))
    print(
        f"packed {w.size} int8 weights into {packed.nbytes} bytes "
        f"({8 * packed.nbytes / w.size:.2f} bits/weight), "
        f"max |w_hat - w| = {int(err.max())}"
    )
    l = VGG16_LAYERS[0]
    full = trim_memory_accesses(l, PAPER_ENGINE).weight_reads
    msr = trim_memory_accesses(l, PAPER_ENGINE, weight_bits=5).weight_reads
    print(
        f"{l.name} weight reads: {full:.3f}M (int8) -> {msr:.3f}M "
        f"(int5, exactly 5/8)"
    )


if __name__ == "__main__":
    demo_trim_dataflow()
    demo_kernel()
    demo_lm()
    demo_int5()
