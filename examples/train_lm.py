"""End-to-end LM training driver (~100M-class model).

  PYTHONPATH=src python examples/train_lm.py --steps 50          # demo
  PYTHONPATH=src python examples/train_lm.py --full --steps 300  # ~130M

--full trains the REAL mamba2-130m assigned config (130M params) on the
synthetic Markov stream with checkpointing + auto-resume; the default is a
~15M cut of the same family so the demo finishes in minutes on one CPU
core. On a real slice this script runs unchanged under
jax.distributed.initialize() with the production mesh.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.distributed import (
    StepConfig,
    TrainLoopConfig,
    make_train_state,
    make_train_step,
    train_loop,
)
from repro.nn.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument(
        "--full", action="store_true", help="the real mamba2-130m config (slow on CPU)"
    )
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m").with_overrides(dtype=jnp.float32, remat="none")
    if not args.full:
        cfg = cfg.with_overrides(
            d_model=256, n_layers=8, vocab=8192, ssm_chunk=64, name="mamba2-15m-demo"
        )
    model = build_model(cfg)
    n_params = cfg.param_count_estimate()
    print(
        f"[train_lm] {cfg.name}: ~{n_params/1e6:.0f}M params, "
        f"{cfg.n_layers}L d={cfg.d_model}"
    )

    state = make_train_state(model, jax.random.PRNGKey(0))
    scfg = StepConfig(
        peak_lr=args.lr,
        warmup_steps=max(args.steps // 10, 5),
        total_steps=args.steps,
    )
    step = jax.jit(make_train_step(model, scfg), donate_argnums=(0,))
    ds = SyntheticLMDataset(
        vocab=cfg.vocab, seq_len=args.seq + 1, global_batch=args.batch
    )
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 4, 10),
        ckpt_dir=args.ckpt_dir,
        log_every=10,
    )
    out = train_loop(step, state, ds, loop_cfg)
    losses = [h["loss"] for h in out["history"]]
    print(
        f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
        f"{len(losses)} steps"
        + (f" (resumed from {out['resumed_from']})" if out["resumed_from"] else "")
    )


if __name__ == "__main__":
    main()
